"""Sharding rules + a subprocess mini-dry-run on 16 host devices (the
multi-device logic cannot run in-process: jax locks the device count)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import shard_spec_for_path


class _FakeMesh:
    def __init__(self, data=16, model=16):
        self.shape = {"data": data, "model": model}
        self.axis_names = ("data", "model")


MESH = _FakeMesh()


def test_rules_tp_and_fsdp():
    cfg = get_config("qwen3_8b")
    s = shard_spec_for_path("blocks/attn/q/w", (36, 4096, 4096), MESH, cfg)
    assert tuple(s) == (None, "data", "model")      # heads 32 % 16 == 0
    s = shard_spec_for_path("blocks/attn/k/w", (36, 4096, 1024), MESH, cfg)
    assert "model" not in tuple(s)                  # kv 8 % 16 != 0 -> repl
    s = shard_spec_for_path("embed/emb", (152064, 4096), MESH, cfg)
    assert tuple(s) == ("model", "data")
    s = shard_spec_for_path("blocks/ln1/g", (36, 4096), MESH, cfg)
    assert tuple(s) == ()


def test_rules_moe_ep_vs_expert_tp():
    qw = get_config("qwen3_moe_235b")               # 128 experts: EP
    s = shard_spec_for_path("blocks/moe/gate", (94, 128, 4096, 1536),
                            MESH, qw)
    assert tuple(s)[1] == "model"
    gk = get_config("grok1_314b")                   # 8 experts: expert-TP
    s = shard_spec_for_path("blocks/moe/gate", (64, 8, 6144, 32768),
                            MESH, gk)
    assert tuple(s)[-1] == "model" and "model" not in tuple(s)[:-1]


def test_gemma_attention_fully_replicated_across_tp():
    cfg = get_config("gemma3_1b")                   # 4 q heads, 1 kv head
    for path, shape in [("blocks/attn/q/w", (26, 1152, 1024)),
                        ("blocks/attn/k/w", (26, 1152, 256)),
                        ("blocks/attn/o/w", (26, 1024, 1152))]:
        s = shard_spec_for_path(path, shape, MESH, cfg)
        assert "model" not in tuple(s), (path, s)


@pytest.mark.slow
def test_mini_dryrun_16_devices(tmp_path):
    """Lower+compile a reduced train step on a (4,4) mesh in a subprocess;
    assert collectives exist and memory analysis is sane."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import json, sys
        import jax, jax.numpy as jnp
        sys.path.insert(0, "src")
        from repro.configs import get_config, Shape
        from repro.launch import steps
        from repro.launch.hlo_analysis import collective_bytes
        from repro.launch.mesh import mesh_ctx
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        cfg = get_config("qwen3_8b", reduced=True)
        shape = Shape("t", 128, 8, "train")
        with mesh_ctx(mesh):
            jitted, args = steps.build_train_step(cfg, shape, mesh)
            compiled = jitted.lower(*args).compile()
        mem = compiled.memory_analysis()
        cb = collective_bytes(compiled.as_text())
        print(json.dumps({"temp": mem.temp_size_in_bytes,
                          "coll": cb["total"]}))
    """)
    p = subprocess.run([sys.executable, "-c", script], cwd="/root/repo",
                       capture_output=True, text=True, timeout=420)
    assert p.returncode == 0, p.stderr[-2000:]
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["coll"] > 0, "sharded train step must contain collectives"
    assert 0 < out["temp"] < 16 * 2 ** 30


def test_hlo_collective_parser():
    txt = """
  %all-reduce.271 = f32[8,512]{1,0} all-reduce(%wrapped), channel_id=1
  %all-gather.5 = bf16[128,64]{1,0} all-gather(%p), replica_groups=[4,4]
  %meta = f32[2]{0} add(%a, %b), metadata={op_name="not all-reduce here"}
  %ar2 = (f32[4,4]{1,0}, f32[2,2]{1,0}) all-reduce-start(%x, %y)
"""
    from repro.launch.hlo_analysis import collective_bytes
    cb = collective_bytes(txt)
    assert cb["all-reduce"] == (8 * 512 * 4) * 2 + (16 * 4 + 4 * 4) * 2
    assert cb["all-gather"] == 128 * 64 * 2
    assert cb["total"] == cb["all-reduce"] + cb["all-gather"]
