"""End-to-end behaviour tests for the whole system: the training launcher
(with and without the fusion-mapper integration) and the serving loop."""
import numpy as np
import pytest

from repro.launch.serve import serve_greedy
from repro.launch.train import mapper_microbatch, train
from repro.configs import get_config


def test_train_e2e_loss_decreases(tmp_path):
    loop, _ = train("qwen3_8b", steps=30, global_batch=4, seq_len=64,
                    reduced=True, ckpt_dir=str(tmp_path), lr=2e-3)
    first, last = loop.losses[0][1], loop.losses[-1][1]
    assert np.isfinite(last)
    assert last < first, (first, last)


def test_train_with_fusion_mapper(tmp_path):
    """The paper's technique as a trainer feature: mapper-chosen gradient
    accumulation produces the same-shaped run and finite losses."""
    loop, info = train("gemma3_1b", steps=12, global_batch=8, seq_len=64,
                       reduced=True, ckpt_dir=str(tmp_path),
                       use_mapper=True, act_budget_mb=4.0)
    assert info is not None
    assert 8 % info["micro_batch"] == 0
    assert info["grad_accum"] == 8 // info["micro_batch"]
    assert np.isfinite(loop.losses[-1][1])


def test_train_resumes_from_checkpoint(tmp_path):
    loop1, _ = train("rwkv6_3b", steps=10, global_batch=2, seq_len=32,
                     reduced=True, ckpt_dir=str(tmp_path))
    loop2, _ = train("rwkv6_3b", steps=16, global_batch=2, seq_len=32,
                     reduced=True, ckpt_dir=str(tmp_path))
    assert loop2.start_step == 10            # resumed, not restarted


@pytest.mark.parametrize("arch", ["qwen3_8b", "whisper_base", "hymba_15b"])
def test_serve_e2e(arch):
    out = serve_greedy(arch, batch=2, prompt_len=16, gen_len=6,
                       reduced=True)
    assert out["tokens"].shape == (2, 6)
    assert out["tok_per_s"] > 0
