"""Device-resident one-shot inference: the fused scan rollout must be an
exact stand-in for the host reference path (DESIGN.md §9).

 - ``prefix_step`` carry matches ``prefix_trace`` at every t;
 - ``prefix_probe_peak`` equals the composed step+out probe;
 - ``dt_decode_step`` with a KV cache matches full-sequence ``dt_apply``;
 - ``s2s_decode_step`` replays teacher-forced ``s2s_apply`` exactly;
 - the fused rollout emits strategies bit-identical to the host loop
   (guard off and on), and the batched front-end matches per-condition runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DTConfig, FusionEnv, PAPER_ACCEL, S2SConfig,
                        dnnfuser_infer, dnnfuser_infer_batch,
                        dnnfuser_infer_fused, dt_apply, dt_cache_init,
                        dt_decode_step, dt_init, dt_prefill, s2s_apply,
                        s2s_decode_start, s2s_decode_step, s2s_encode,
                        s2s_infer_fused, s2s_init)
from repro.core import cost_model as cm
from repro.workloads import mobilenet_v2, resnet18, vgg16

HW = PAPER_ACCEL
MB = 2 ** 20
CFG = DTConfig(max_steps=20)


# --- incremental prefix evaluator ------------------------------------------

@pytest.mark.parametrize("wl_fn", [vgg16, resnet18, mobilenet_v2])
def test_prefix_scan_matches_prefix_trace(wl_fn):
    w = wl_fn()
    wl = cm.pack_workload(w, HW, 64)
    rng = np.random.default_rng(0)
    for _ in range(8):
        s = cm.random_strategy(rng, w.n, 64, 64, p_sync=0.35)
        tr = cm.prefix_trace(wl, jnp.asarray(s), 64.0, 20 * MB, HW)
        sc, fin = cm.prefix_scan(wl, jnp.asarray(s), 64.0, 20 * MB, HW)
        for k in ("latency", "peak_mem", "traffic"):
            np.testing.assert_allclose(
                np.asarray(getattr(sc, k)), np.asarray(getattr(tr, k)),
                rtol=1e-5, atol=1e-3, err_msg=k)
        assert (np.asarray(sc.n_groups) == np.asarray(tr.n_groups)).all()
        full = cm.evaluate(wl, jnp.asarray(s), 64.0, 20 * MB, HW)
        np.testing.assert_allclose(float(fin.latency), float(full.latency),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(fin.peak_mem), float(full.peak_mem),
                                   rtol=1e-5)
        assert bool(fin.valid) == bool(full.valid)


def test_prefix_probe_peak_matches_composed_probe():
    w = resnet18()
    wl = cm.pack_workload(w, HW, 64)
    consts = cm.prefix_consts(wl, 64.0, 20 * MB, HW)
    carry = cm.prefix_init(consts)
    rng = np.random.default_rng(1)
    s = cm.random_strategy(rng, w.n, 64, 64)
    for t in range(w.n + 1):
        for a in (1, 5, 32, 64):
            ref = cm.prefix_out(
                consts, cm.prefix_step(consts, carry, a, HW), HW).peak_mem
            fast = cm.prefix_probe_peak(consts, carry, a, HW)
            assert float(ref) == float(fast), (t, a)
        carry = cm.prefix_step(consts, carry, int(s[t]), HW)


# --- cached decode vs full-sequence forward --------------------------------

def test_dt_decode_step_matches_dt_apply():
    params = dt_init(jax.random.PRNGKey(0), CFG)
    rng = np.random.default_rng(0)
    T = CFG.max_steps
    rtg = jnp.asarray(rng.random((1, T)), jnp.float32)
    states = jnp.asarray(rng.random((1, T, 8)), jnp.float32)
    actions = jnp.asarray(rng.random((1, T)), jnp.float32)
    full = np.asarray(dt_apply(params, CFG, rtg, states, actions))[0]
    cache = dt_cache_init(CFG)
    pred, cache = dt_prefill(params, CFG, cache, rtg[:, 0], states[:, 0])
    preds = [float(pred[0])]
    for t in range(1, T):
        pred, cache = dt_decode_step(params, CFG, cache, rtg[:, t],
                                     states[:, t], actions[:, t - 1])
        preds.append(float(pred[0]))
    np.testing.assert_allclose(np.array(preds), full, atol=1e-5)


def test_s2s_decode_step_matches_s2s_apply():
    cfg = S2SConfig(max_steps=20)
    params = s2s_init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    T = cfg.max_steps
    rtg = jnp.asarray(rng.random((1, T)), jnp.float32)
    states = jnp.asarray(rng.random((1, T, 8)), jnp.float32)
    actions = jnp.asarray(rng.random((1, T)), jnp.float32)
    full = np.asarray(s2s_apply(params, cfg, rtg, states, actions))[0]
    cache = s2s_decode_start(s2s_encode(params, cfg, rtg, states))
    prev = jnp.zeros((1,), jnp.float32)
    preds = []
    for t in range(T):
        pred, cache = s2s_decode_step(params, cfg, cache, rtg[:, t],
                                      states[:, t], prev)
        preds.append(float(pred[0]))
        prev = actions[:, t]
    np.testing.assert_allclose(np.array(preds), full, atol=1e-5)


# --- fused rollout vs host reference ---------------------------------------

def _biased(params, bias):
    """Shift the action head so the model asks for large micro-batches
    (forces the budget-repair guard to engage)."""
    p = jax.tree_util.tree_map(lambda x: x, params)
    p["head"] = dict(params["head"])
    p["head"]["b"] = params["head"]["b"] + bias
    return p


@pytest.mark.parametrize("wl_fn", [vgg16, resnet18])
def test_fused_rollout_identical_to_host(wl_fn):
    wl = wl_fn()
    for seed in (0, 1):
        params = dt_init(jax.random.PRNGKey(seed), CFG)
        for budget_mb in (12, 20, 48):
            env = FusionEnv(wl, HW, batch=64, budget_bytes=budget_mb * MB,
                            nmax=CFG.max_steps)
            for repair in (False, True):
                h = dnnfuser_infer(params, CFG, env, repair=repair)
                f = dnnfuser_infer_fused(params, CFG, env, repair=repair)
                assert (h.strategy == f.strategy).all(), \
                    (seed, budget_mb, repair)
                np.testing.assert_allclose(f.latency, h.latency, rtol=1e-5)
                assert f.valid == h.valid
                assert f.n_model_calls == wl.n + 1


def test_fused_guard_repairs_over_budget_strategies():
    wl = vgg16()
    params = _biased(dt_init(jax.random.PRNGKey(0), CFG), 0.9)
    for budget_mb in (4, 6, 10):
        env = FusionEnv(wl, HW, batch=64, budget_bytes=budget_mb * MB,
                        nmax=CFG.max_steps)
        raw = dnnfuser_infer_fused(params, CFG, env, repair=False)
        assert not raw.valid        # the biased model overshoots ...
        h = dnnfuser_infer(params, CFG, env, repair=True)
        f = dnnfuser_infer_fused(params, CFG, env, repair=True)
        assert f.valid              # ... and the on-device guard repairs it
        assert f.peak_mem <= env.budget_bytes
        assert (h.strategy == f.strategy).all()


def test_infer_batch_matches_single_condition_runs():
    wl = resnet18()
    params = dt_init(jax.random.PRNGKey(2), CFG)
    batches = np.array([64.0, 64.0, 32.0, 16.0], np.float32)
    budgets = np.array([12.0, 32.0, 20.0, 20.0], np.float32) * MB
    env0 = FusionEnv(wl, HW, batch=64, budget_bytes=32 * MB,
                     nmax=CFG.max_steps)
    out = dnnfuser_infer_batch(params, CFG, env0, batches, budgets)
    assert out["strategy"].shape == (4, CFG.max_steps)
    for i in range(len(batches)):
        env = FusionEnv(wl, HW, batch=int(batches[i]),
                        budget_bytes=float(budgets[i]), nmax=CFG.max_steps)
        one = dnnfuser_infer_fused(params, CFG, env)
        assert (out["strategy"][i] == one.strategy).all(), i
        np.testing.assert_allclose(out["latency"][i], one.latency,
                                   rtol=1e-5)


def test_s2s_fused_rollout_valid():
    cfg = S2SConfig(max_steps=20)
    params = s2s_init(jax.random.PRNGKey(3), cfg)
    env = FusionEnv(resnet18(), HW, batch=64, budget_bytes=16 * MB,
                    nmax=cfg.max_steps)
    res = s2s_infer_fused(params, cfg, env, repair=True)
    assert res.valid and np.isfinite(res.latency)
    assert res.peak_mem <= env.budget_bytes
