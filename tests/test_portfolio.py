"""Warm-started searcher portfolio contracts (DESIGN §17): determinism,
warm-beats-cold at a fixed budget, grid==single RNG reproducibility, and
the oracle cross-check."""
import numpy as np
import pytest

import _adversarial as adv
from repro.core import PortfolioConfig, de_search_grid, cmaes_search_grid
from repro.core import cost_model as cm
from repro.core.accel import ACCEL_ZOO
from repro.core.env import encode_action
from repro.workloads import resnet18, tiny_cnn

MB = 2.0 ** 20
NMAX = 32
CFG = PortfolioConfig(population=16, generations=10, seed=0)
SEARCHERS = {"de": de_search_grid, "cmaes": cmaes_search_grid}


def _grid():
    wls = [tiny_cnn(), resnet18()]
    hws = [ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"]]
    batches = np.asarray([64.0, 32.0], np.float32)
    budgets = np.asarray([4 * MB, 10 * MB], np.float32)
    return wls, hws, batches, budgets


def _proposal(wls, batches):
    out = []
    for w, b in zip(wls, batches):
        s = np.full(NMAX, cm.SYNC, np.int32)
        s[: w.n + 1] = max(1, int(b) // 8)
        out.append(s)
    return np.stack(out)


@pytest.mark.parametrize("method", sorted(SEARCHERS))
def test_portfolio_deterministic(method):
    wls, hws, batches, budgets = _grid()
    run = SEARCHERS[method]
    a = run(wls, hws, batches, budgets, nmax=NMAX, cfg=CFG)
    b = run(wls, hws, batches, budgets, nmax=NMAX, cfg=CFG)
    assert np.array_equal(a.strategies, b.strategies)
    assert np.array_equal(a.latency, b.latency)
    assert np.array_equal(a.history, b.history)


@pytest.mark.parametrize("method", sorted(SEARCHERS))
def test_portfolio_valid_and_shapes(method):
    wls, hws, batches, budgets = _grid()
    res = SEARCHERS[method](wls, hws, batches, budgets, nmax=NMAX, cfg=CFG)
    C = len(batches)
    assert res.strategies.shape == (C, NMAX)
    assert res.history.shape == (CFG.generations, C)
    assert res.valid.all()                    # easy budgets: must solve
    assert (res.latency > 0).all()
    assert res.n_evals == C * CFG.population * (CFG.generations + 1) + C
    # history is the best-so-far curve: monotone non-increasing
    h = res.history
    assert (h[1:] <= h[:-1] + 1e-12).all()
    assert np.allclose(h[-1], res.latency)


@pytest.mark.parametrize("method", sorted(SEARCHERS))
def test_warm_start_never_worse_than_proposal(method):
    """Elitism through the exact warm seed: the returned strategy's
    fitness is >= the proposal's, so a valid proposal can only improve."""
    wls, hws, batches, budgets = _grid()
    init = _proposal(wls, batches)
    res = SEARCHERS[method](wls, hws, batches, budgets, nmax=NMAX,
                            cfg=CFG, init_strategies=init)
    packed = cm.stack_workloads(
        [cm.pack_workload(w, h, NMAX) for w, h in zip(wls, hws)])
    pout = cm.evaluate_grid(packed, init[:, None, :], batches, budgets,
                            [h for h in hws])
    for c in range(len(batches)):
        if bool(np.asarray(pout.valid)[c, 0]):
            assert res.valid[c]
            assert res.latency[c] <= float(
                np.asarray(pout.latency)[c, 0]) + 1e-12


def test_warm_beats_cold_at_fixed_budget():
    """The §17 escalation claim at test scale: at the same population /
    seed / evaluation budget, the warm-started DE reaches any cost BOTH
    runs eventually achieve in strictly fewer total generations, and its
    first-generation best already matches or beats the cold run's."""
    wls, hws, batches, budgets = _grid()
    init = _proposal(wls, batches)
    warm = de_search_grid(wls, hws, batches, budgets, nmax=NMAX, cfg=CFG,
                          init_strategies=init)
    cold = de_search_grid(wls, hws, batches, budgets, nmax=NMAX, cfg=CFG)
    tol = 1.0 + 1e-6
    # anytime advantage at the start: the proposal is a better incumbent
    # than anything a random first generation finds
    assert (warm.history[0] <= cold.history[0] * tol).all()
    # generations-to-reach a per-cell target both runs achieve
    reach_w = reach_c = 0
    for c in range(len(batches)):
        target = max(warm.latency[c], cold.latency[c]) * tol
        reach_w += int(np.argmax(warm.history[:, c] <= target))
        reach_c += int(np.argmax(cold.history[:, c] <= target))
    assert reach_w < reach_c


def test_grid_reproduces_single_condition_run():
    """Per-condition RNG streams: grid row c bit-matches a C=1 run with
    salts=[c] — the property engine escalation's determinism rides on."""
    wls, hws, batches, budgets = _grid()
    grid = de_search_grid(wls, hws, batches, budgets, nmax=NMAX, cfg=CFG)
    c = 1
    single = de_search_grid([wls[c]], [hws[c]], batches[c:c + 1],
                            budgets[c:c + 1], nmax=NMAX, cfg=CFG,
                            salts=[c])
    assert np.array_equal(grid.strategies[c], single.strategies[0])
    assert grid.latency[c] == single.latency[0]
    assert np.array_equal(grid.history[:, c], single.history[:, 0])


def test_warm_seed_roundtrip_exact():
    """encode_action must embed the proposal losslessly: decoding the
    encoded proposal through the portfolio's genome rules returns it
    bit-for-bit (the warm start is the proposal, not an approximation)."""
    from repro.core.portfolio import _decode_grid
    import jax.numpy as jnp
    w = resnet18()
    s = np.full(NMAX, cm.SYNC, np.int32)
    s[: w.n + 1] = [max(1, (i * 7) % 64) if i % 3 else cm.SYNC
                    for i in range(w.n + 1)]
    s[0] = 16
    y = encode_action(s, 64)
    dec = np.asarray(_decode_grid(
        jnp.asarray(y)[None, None, :], jnp.asarray([64.0]),
        jnp.asarray(np.arange(NMAX)[None, :] <= w.n)))[0, 0]
    assert np.array_equal(dec, s)


def test_portfolio_never_below_certified_optimum():
    """Oracle cross-check on the adversarial set: the portfolio's exact
    latency must stay >= the certified optimum per solvable condition."""
    from repro.core import optimal as op
    for name, wl, batch, budget, pack_hw, serve_hw in adv.cases():
        if name.startswith("boundary") or pack_hw is not serve_hw:
            continue
        wl_np = adv.packed(wl, serve_hw)
        try:
            opt = op.optimal_search(wl_np, batch, float(budget), serve_hw,
                                    front_cap=4096)
        except RuntimeError:
            continue
        res = de_search_grid([wl], [serve_hw], [float(batch)],
                             [float(budget)], nmax=adv.NMAX, cfg=CFG)
        if res.valid[0] and opt.valid:
            assert res.latency[0] >= opt.latency * (1 - 1e-5), name
