"""G-Sampler + baseline searcher behaviour."""
import numpy as np
import pytest

from repro.core import (FusionEnv, GSamplerConfig, PAPER_ACCEL,
                        BASELINE_METHODS, gsampler_search)
from repro.core.baselines import random_search
from repro.workloads import resnet18, vgg16

MB = 2 ** 20


@pytest.fixture(scope="module")
def env():
    return FusionEnv(resnet18(), PAPER_ACCEL, batch=64,
                     budget_bytes=20 * MB)


def test_gsampler_valid_and_beats_baseline(env):
    res = gsampler_search(env, GSamplerConfig(generations=25, seed=0))
    assert res.valid
    assert res.speedup > 1.0
    assert res.n_evals <= 26 * 40   # sampling budget honored


def test_gsampler_beats_random(env):
    res = gsampler_search(env, GSamplerConfig(generations=25, seed=0))
    rnd = random_search(env, budget=1000, seed=0)
    gs_obj = res.latency if res.valid else np.inf
    rnd_obj = rnd.latency if rnd.valid else np.inf
    assert gs_obj < rnd_obj


def test_gsampler_improves_over_generations(env):
    res = gsampler_search(env, GSamplerConfig(generations=30, seed=1))
    hist = [h for h in res.history if h > 0]
    assert hist and max(hist) >= hist[0]


def test_gsampler_respects_budget_constraint(env):
    for seed in range(3):
        res = gsampler_search(env, GSamplerConfig(generations=15, seed=seed))
        assert res.peak_mem <= env.budget_bytes * (1 + 1e-6)


@pytest.mark.parametrize("method", sorted(BASELINE_METHODS))
def test_baselines_run_within_budget(env, method):
    r = BASELINE_METHODS[method](env, budget=400, seed=0)
    assert r.n_evals <= 400
    assert np.isfinite(r.latency)


def test_elites_are_distinct_and_valid(env):
    res = gsampler_search(env, GSamplerConfig(generations=20, seed=2),
                          top_k=6)
    seen = set()
    for s in res.elites:
        _, peak, valid = env.speedup(s)
        assert valid
        seen.add(s[: env.n + 1].tobytes())
    assert len(seen) == len(res.elites)


# ---------------------------------------------------------------------------
# Evaluator-backend equivalence (DESIGN §13): the grid G-Sampler and the
# teacher-corpus pipeline must be BIT-identical per seed whether fitness
# runs on the XLA evaluator or the Pallas fusion_eval kernel (interpret on
# this CPU container) — the property that makes the backend switch safe to
# flip in production without regenerating a single corpus.
# ---------------------------------------------------------------------------

_BE_CFG = GSamplerConfig(population=8, generations=4, elite=2,
                         repair_tries=2, seed=3)


def _grid_args():
    from repro.core.accel import ACCEL_ZOO
    from repro.workloads import tiny_cnn
    wls = [tiny_cnn(), tiny_cnn()]
    hws = [PAPER_ACCEL, ACCEL_ZOO["datacenter"]]
    return wls, hws, [8.0, 8.0], [2 * MB, 4 * MB]


def test_gsampler_grid_backend_equivalence():
    from repro.core import gsampler_search_grid
    wls, hws, batches, budgets = _grid_args()
    res = {ev: gsampler_search_grid(wls, hws, batches, budgets, nmax=16,
                                    cfg=_BE_CFG, top_k=4, evaluator=ev)
           for ev in ("xla", "pallas")}
    for field in ("strategies", "latency", "peak_mem", "speedup", "valid",
                  "history", "baseline_latency"):
        np.testing.assert_array_equal(getattr(res["xla"], field),
                                      getattr(res["pallas"], field),
                                      err_msg=field)
    assert res["xla"].valid.any()           # the grid actually solved


def test_teacher_corpus_backend_equivalence():
    from repro.core.accel import ACCEL_ZOO
    from repro.core.dataset import generate_teacher_corpus
    from repro.workloads import tiny_cnn
    ds = {ev: generate_teacher_corpus(
              [tiny_cnn()], [PAPER_ACCEL, ACCEL_ZOO["datacenter"]], batch=8,
              budgets_mb=[2.0], max_steps=16, top_k=4, ga_cfg=_BE_CFG,
              seed=5, evaluator=ev)
          for ev in ("xla", "pallas")}
    for field in ("rtg", "states", "actions", "mask", "t0", "hw"):
        np.testing.assert_array_equal(getattr(ds["xla"], field),
                                      getattr(ds["pallas"], field),
                                      err_msg=field)
    assert ds["xla"].meta == ds["pallas"].meta
    assert len(ds["xla"]) > 0


# ---------------------------------------------------------------------------
# optimality lower bound (DESIGN §16): the certified exact optimum bounds
# the entire search stack from below — a G-Sampler "improvement" past it
# would mean the evaluator and the search disagree about the map-space.
# ---------------------------------------------------------------------------

import _adversarial as adv
from repro.core import optimal as op
from repro.core.accel import ACCEL_ZOO


@pytest.mark.parametrize(
    "case", [c for c in adv.cases() if c[4] is c[5]], ids=lambda c: c[0])
def test_gsampler_never_below_certified_optimum(case):
    name, wl, batch, budget, pack_hw, serve_hw = case
    env = FusionEnv(wl, serve_hw, batch=batch, budget_bytes=budget,
                    nmax=adv.NMAX)
    opt = op.optimal_mapping(env, certify=False)
    res = gsampler_search(env, GSamplerConfig(generations=10,
                                              population=128, seed=0))
    if not opt.valid:
        assert not res.valid, (name, "GA found a mapping the oracle proved "
                               "infeasible")
        return
    if res.valid:
        # f32 search latency vs f64 optimum: float tolerance only
        assert float(res.latency) >= opt.latency * (1 - 1e-4), \
            (name, float(res.latency), opt.latency)


def test_gsampler_reaches_optimum_on_tiny_chain():
    """On a 3-layer chain a budgeted GA should actually FIND the optimum —
    the bound above is tight, not vacuous."""
    wl = adv.mixed_magnitude()
    env = FusionEnv(wl, ACCEL_ZOO["edge"], batch=16,
                    budget_bytes=24 * adv.MB, nmax=adv.NMAX)
    opt = op.optimal_mapping(env, certify=False)
    res = gsampler_search(env, GSamplerConfig(generations=30,
                                              population=256, seed=0))
    assert res.valid and opt.valid
    assert float(res.latency) <= opt.latency * (1 + 1e-4)
