"""Optimality certification (DESIGN §16): the exact DP oracle pinned
bit-for-bit against exhaustive brute-force enumeration, plus the f32
certification contract.

The property tests run under hypothesis when the 'test' extra is
installed and fall back to a fixed seeded-numpy sweep otherwise, so
the oracle is exercised in BOTH environments (CI installs hypothesis;
the bare install must not silently skip its only ground-truth check).
"""
import numpy as np
import pytest

import _adversarial as adv
from repro.core import cost_model as cm
from repro.core import optimal as op
from repro.core import ref_model
from repro.core.accel import ACCEL_ZOO, PAPER_ACCEL
from repro.core.env import FusionEnv
from repro.workloads.layer import Layer, Workload
from repro.workloads import tiny_cnn

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

MB = 2.0 ** 20
NMAX = 8
ACCELS = sorted(ACCEL_ZOO)


# ---------------------------------------------------------------------------
# random small chains
# ---------------------------------------------------------------------------


def _chain_from_spec(n, layer_specs):
    layers = []
    for i, (macs, out_e, w_e, skip) in enumerate(layer_specs[:n]):
        src = skip if (skip >= 0 and skip < i + 1) else -1
        layers.append(Layer.op(f"l{i}", macs=float(macs),
                               out_elems=float(out_e), w_elems=float(w_e),
                               shape6=(4, 4, 4, 4, 1, 1), skip_src=src))
    return Workload(name=f"rand{n}", layers=layers, input_elems=64.0,
                    input_shape6=(4, 4, 4, 4, 1, 1))


def _check_dp_vs_brute(wl, batch, budget, hw):
    """The core pin: DP optimum == brute-force optimum, bit-exact."""
    wl_np = {k: np.asarray(v)
             for k, v in cm.pack_workload(wl, hw, NMAX).items()}
    dp = op.optimal_search(wl_np, batch, budget, hw)
    bf = op.brute_force_optimal(wl_np, batch, budget, hw)
    assert dp.valid == bf.valid, (dp, bf)
    if dp.valid:
        assert dp.latency == bf.latency, \
            f"DP {dp.latency!r} != brute {bf.latency!r}"
    # argmin validity: the DP's own strategy re-evaluates to its cost
    ref = ref_model.evaluate_ref(op.scaled_wl_np(wl_np, hw), dp.strategy,
                                 batch, budget, hw)
    assert ref["latency"] == dp.latency and ref["valid"] == dp.valid
    assert ref["peak_mem"] == dp.peak_mem
    return dp, bf


def _run_random_case(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 6))
    specs = [(10.0 ** rng.uniform(2, 7), 10.0 ** rng.uniform(1, 4),
              10.0 ** rng.uniform(1, 4), int(rng.integers(-2, i + 1)))
             for i in range(n)]
    wl = _chain_from_spec(n, specs)
    batch = int(rng.integers(2, 5))
    hw = ACCEL_ZOO[ACCELS[int(rng.integers(0, len(ACCELS)))]]
    budget = float(10.0 ** rng.uniform(-2, 2)) * MB
    _check_dp_vs_brute(wl, batch, budget, hw)


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_dp_matches_brute_force_random_chains(seed):
        """Random chains (n<=5, skips, random accel/budget): the DP's
        optimum latency/validity/peak must equal exhaustive enumeration."""
        _run_random_case(seed)

else:

    @pytest.mark.parametrize("seed", range(25))
    def test_dp_matches_brute_force_random_chains(seed):
        """Seeded fallback of the hypothesis sweep (no 'test' extra)."""
        _run_random_case(seed)


def test_dp_budget_boundary_bit_flip():
    """Budget EXACTLY at the optimum's peak stays feasible (<=); one ulp
    below must change the argmin or flip to invalid — on both oracles."""
    wl = adv.depthwise_capped()
    hw = ACCEL_ZOO["edge"]
    wl_np = adv.packed(wl, hw)
    loose = op.brute_force_optimal(wl_np, 8, 1e30, hw)
    at = float(loose.peak_mem)
    dp_at, bf_at = _check_dp_vs_brute(wl, 8, at, hw)
    assert dp_at.valid and dp_at.latency == loose.latency
    below = np.nextafter(at, 0.0)
    dp_lo, bf_lo = _check_dp_vs_brute(wl, 8, below, hw)
    assert (not dp_lo.valid) or dp_lo.peak_mem <= below


@pytest.mark.parametrize("case", adv.cases(), ids=lambda c: c[0])
def test_dp_matches_brute_force_adversarial(case):
    """The shared adversarial set (single-layer, boundary budgets, BPE
    mismatch, mixed magnitudes, depthwise caps): DP == brute force."""
    name, wl, batch, budget, pack_hw, serve_hw = case
    wl_np = adv.packed(wl, pack_hw)
    dp = op.optimal_search(wl_np, batch, budget, serve_hw)
    bf = op.brute_force_optimal(wl_np, batch, budget, serve_hw)
    assert dp.valid == bf.valid, name
    if dp.valid:
        assert dp.latency == bf.latency, name


def test_position0_value_is_cost_irrelevant():
    """Position 0 is the network input: its strategy slot must not affect
    any evaluator (the oracle pins it to ``batch`` by convention)."""
    wl = adv.mixed_magnitude()
    hw = ACCEL_ZOO["edge"]
    wl_np = adv.packed(wl, hw)
    dp = op.optimal_search(wl_np, 16, 24 * MB, hw)
    s2 = dp.strategy.copy()
    s2[0] = 1
    ref = ref_model.evaluate_ref(op.scaled_wl_np(wl_np, hw), s2, 16,
                                 24 * MB, hw)
    assert ref["latency"] == dp.latency


# ---------------------------------------------------------------------------
# certification against the production f32 stack
# ---------------------------------------------------------------------------


def test_optimal_mapping_certified_against_f32():
    """The certify path: DT-serving's evaluator (f32 XLA) agrees with the
    f64 DP winner within float tolerance, and the certified CostOut is
    attached."""
    env = FusionEnv(tiny_cnn(), ACCEL_ZOO["edge"], batch=8,
                    budget_bytes=4 * MB, nmax=16)
    res = op.optimal_mapping(env)
    assert res.valid and res.certified is not None
    assert np.isclose(float(res.certified.latency), res.latency,
                      rtol=1e-4)
    assert bool(res.certified.valid)


def test_optimal_grid_matches_per_condition_search():
    """optimal_grid == per-condition optimal_search, plus one-call f32
    certification across a heterogeneous grid."""
    wls = [tiny_cnn(), adv.mixed_magnitude()]
    hws = [ACCEL_ZOO["edge"], ACCEL_ZOO["datacenter"]]
    grid = op.optimal_grid(wls, hws, [8, 16], [4 * MB, 24 * MB], nmax=16)
    assert len(grid) == 2
    for r, w, a, b, g in zip(grid, wls, hws, [8, 16], [4 * MB, 24 * MB]):
        wl_np = {k: np.asarray(v)
                 for k, v in cm.pack_workload(w, a, 16).items()}
        solo = op.optimal_search(wl_np, b, g, a)
        assert r.latency == solo.latency and r.valid == solo.valid
        assert r.certified is not None


def test_optimal_teacher_never_above_gsampler():
    """Sanity direction of the whole exercise: the certified optimum is a
    lower bound on what the stochastic teacher can find."""
    from repro.core import GSamplerConfig, gsampler_search
    env = FusionEnv(tiny_cnn(), ACCEL_ZOO["edge"], batch=8,
                    budget_bytes=4 * MB, nmax=16)
    res = op.optimal_mapping(env)
    gs = gsampler_search(env, GSamplerConfig(generations=8, population=64,
                                             seed=0))
    assert gs.valid
    assert res.latency <= float(gs.latency) * (1 + 1e-9)


# ---------------------------------------------------------------------------
# enumeration contract
# ---------------------------------------------------------------------------


def test_enumerate_strategies_counts_and_limit():
    pop = op.enumerate_strategies(2, 3, NMAX)
    assert pop.shape == ((3 + 1) ** 2, NMAX)
    assert np.all(pop[:, 0] == 3)
    assert np.all(pop[:, 3:] == cm.SYNC)
    uniq = {row.tobytes() for row in pop}
    assert len(uniq) == len(pop)
    with pytest.raises(ValueError):
        op.enumerate_strategies(8, 64, NMAX, limit=1000)


def test_front_cap_raises_rather_than_approximates():
    """An exploding Pareto front must be a hard error, never a silently
    truncated 'optimum'."""
    wl = tiny_cnn()
    hw = ACCEL_ZOO["edge"]
    wl_np = {k: np.asarray(v)
             for k, v in cm.pack_workload(wl, hw, 16).items()}
    with pytest.raises(RuntimeError, match="front"):
        op.optimal_search(wl_np, 64, 16 * MB, hw, front_cap=1)
