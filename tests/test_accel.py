"""Accelerator zoo + traced hardware vector (DESIGN.md §11).

The §11 refactor turned the accelerator from a static jit argument into a
traced condition; these tests pin the invariants that keep that refactor
from silently drifting the cost model:

 - ``accel_features`` is a normalized, INVERTIBLE encoding of every zoo
   preset (round-trip through ``accel_from_features``);
 - ``hw_array``/``hw_from_array``/``stack_hw`` round-trip exactly;
 - ``with_buffer_mb`` composes with feature packing (only ``buf_bytes``
   moves);
 - the packed-hw traced path is BIT-EXACT with the Python-float
   ``PAPER_ACCEL`` path on ``tiny_cnn`` (evaluate / baseline / prefix_scan),
   and the grid evaluator with per-condition accelerators matches
   per-condition single evaluations exactly.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core.accel import (ACCEL_ZOO, HW_FEATURE_DIM, HW_FIELDS,
                              PAPER_ACCEL, AccelConfig, accel_features,
                              accel_from_features, as_hw, hw_array,
                              hw_from_array, stack_hw)
from repro.workloads import tiny_cnn

MB = 2 ** 20


# --- feature packing --------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ACCEL_ZOO))
def test_accel_features_normalized_and_invertible(name):
    cfg = ACCEL_ZOO[name]
    f = np.asarray(accel_features(cfg))
    assert f.shape == (HW_FEATURE_DIM,)
    assert np.isfinite(f).all()
    # every zoo preset lies inside the design range -> features in [0, 1]
    assert (f >= 0.0).all() and (f <= 1.0).all(), (name, f)
    back = accel_from_features(f, name)
    assert back.npe == cfg.npe and back.pe_lanes == cfg.pe_lanes
    for fld in HW_FIELDS:
        if fld in ("npe", "pe_lanes"):
            continue
        assert abs(getattr(back, fld) - getattr(cfg, fld)) <= \
            2e-5 * abs(getattr(cfg, fld)), (name, fld)


def test_accel_features_distinguish_zoo_presets():
    feats = {n: tuple(np.round(np.asarray(accel_features(c)), 6))
             for n, c in ACCEL_ZOO.items()}
    assert len(set(feats.values())) == len(ACCEL_ZOO)


def test_hw_array_round_trip_exact():
    for cfg in ACCEL_ZOO.values():
        arr = np.asarray(hw_array(cfg))
        v = hw_from_array(arr)
        np.testing.assert_array_equal(np.asarray(hw_array(v)), arr)
        # AccelConfig -> HwVec (as_hw) agrees with the array path
        w = as_hw(cfg)
        np.testing.assert_array_equal(np.asarray(hw_array(w)), arr)


def test_stack_hw_forms_agree():
    accels = [ACCEL_ZOO["edge"], ACCEL_ZOO["nano"], ACCEL_ZOO["datacenter"]]
    a = stack_hw(accels, 3)
    b = stack_hw(jnp.stack([hw_array(h) for h in accels]), 3)
    np.testing.assert_array_equal(np.asarray(hw_array(a)),
                                  np.asarray(hw_array(b)))
    c = stack_hw(PAPER_ACCEL, 4)                 # broadcast form
    assert np.asarray(c.npe).shape == (4,)
    with pytest.raises(ValueError):
        stack_hw(accels, 2)


def test_with_buffer_mb_interplay():
    for name, cfg in ACCEL_ZOO.items():
        mod = cfg.with_buffer_mb(24.0)
        assert mod.buf_bytes == 24.0 * MB
        assert mod.name == cfg.name
        f0, f1 = (np.asarray(accel_features(c)) for c in (cfg, mod))
        buf_slot = HW_FIELDS.index("buf_bytes")
        moved = np.nonzero(f0 != f1)[0]
        assert set(moved) <= {buf_slot}, (name, moved)
        back = accel_from_features(f1)
        assert abs(back.buf_bytes - 24.0 * MB) <= 2e-5 * 24.0 * MB


# --- cost-model parity: traced hw == static Python-float hw ----------------

def _conds():
    wl = cm.pack_workload(tiny_cnn(), PAPER_ACCEL, 16)
    rng = np.random.default_rng(0)
    strategies = [cm.random_strategy(rng, tiny_cnn().n, 16, 64)
                  for _ in range(6)]
    return wl, strategies


def test_cost_model_parity_traced_vs_static_paper_accel():
    """The §11 refactor must not move a single bit on the default path:
    evaluating with a packed/traced hw vector equals the AccelConfig path
    EXACTLY (same program constants, multiplier exactly 1.0)."""
    wl, strategies = _conds()
    traced = hw_from_array(hw_array(PAPER_ACCEL))
    for s in strategies:
        s = jnp.asarray(s)
        a = cm.evaluate(wl, s, 64.0, 4.0 * MB, PAPER_ACCEL)
        b = cm.evaluate(wl, s, 64.0, 4.0 * MB, traced)
        for k in ("latency", "peak_mem", "traffic"):
            assert float(getattr(a, k)) == float(getattr(b, k)), k
        assert bool(a.valid) == bool(b.valid)
        ta, fa = cm.prefix_scan(wl, s, 64.0, 4.0 * MB, PAPER_ACCEL)
        tb, fb = cm.prefix_scan(wl, s, 64.0, 4.0 * MB, traced)
        np.testing.assert_array_equal(np.asarray(ta.latency),
                                      np.asarray(tb.latency))
        assert float(fa.peak_mem) == float(fb.peak_mem)
    ba = cm.baseline_no_fusion(wl, 64.0, PAPER_ACCEL)
    bb = cm.baseline_no_fusion(wl, 64.0, traced)
    assert float(ba.latency) == float(bb.latency)


def test_grid_matches_per_condition_across_accels():
    """One vmapped grid program over heterogeneous accelerators returns the
    same numbers as per-condition evaluations (incl. a bytes/elem != 1
    preset, exercising the BPE rescale)."""
    w = tiny_cnn()
    accels = [ACCEL_ZOO["edge"], ACCEL_ZOO["mobile"], ACCEL_ZOO["datacenter"]]
    rng = np.random.default_rng(1)
    pops = np.stack([np.stack([cm.random_strategy(rng, w.n, 16, 64)
                               for _ in range(5)]) for _ in accels])
    wls = cm.stack_workloads([cm.pack_workload(w, h, 16) for h in accels])
    batches = jnp.asarray([64.0, 32.0, 64.0])
    budgets = jnp.asarray([4.0 * MB, 2.0 * MB, 8.0 * MB])
    grid = cm.evaluate_grid(wls, jnp.asarray(pops), batches, budgets, accels)
    for c, h in enumerate(accels):
        wl_c = cm.pack_workload(w, h, 16)
        for p in range(pops.shape[1]):
            one = cm.evaluate(wl_c, jnp.asarray(pops[c, p]),
                              batches[c], budgets[c], h)
            assert float(one.latency) == float(grid.latency[c, p]), (c, p)
            assert float(one.peak_mem) == float(grid.peak_mem[c, p]), (c, p)


def test_bpe_rescale_serves_foreign_datatype_packing():
    """A packing made for a 1-byte accel evaluated under a 2-byte accel
    equals packing natively at 2 bytes (the in-graph BPE rescale)."""
    w = tiny_cnn()
    dc = ACCEL_ZOO["datacenter"]
    wl_edge = cm.pack_workload(w, PAPER_ACCEL, 16)   # bytes_per_elem = 1
    wl_dc = cm.pack_workload(w, dc, 16)              # bytes_per_elem = 2
    rng = np.random.default_rng(2)
    for _ in range(4):
        s = jnp.asarray(cm.random_strategy(rng, w.n, 16, 64))
        a = cm.evaluate(wl_edge, s, 64.0, 8.0 * MB, dc)
        b = cm.evaluate(wl_dc, s, 64.0, 8.0 * MB, dc)
        assert float(a.latency) == float(b.latency)
        assert float(a.peak_mem) == float(b.peak_mem)
        assert float(a.traffic) == float(b.traffic)
