"""Gradient polish contracts (DESIGN §17): never worsens, always-valid
re-rounding, opt-out serving bit-exactness, determinism."""
import numpy as np
import pytest

import _adversarial as adv
from repro.core import (FusionEnv, PolishConfig, PAPER_ACCEL,
                        polish_strategy, polish_grid)
from repro.core import cost_model as cm
from repro.core.accel import ACCEL_ZOO
from repro.workloads import resnet18, tiny_cnn, vgg16

MB = 2.0 ** 20
QUICK = PolishConfig(steps=24, snapshots=4)


@pytest.fixture(scope="module")
def env():
    return FusionEnv(tiny_cnn(), ACCEL_ZOO["edge"], batch=64,
                     budget_bytes=4 * MB)


def _uniform(env, mb):
    s = np.full(env.nmax, cm.SYNC, np.int32)
    s[: env.n + 1] = mb
    return s


def test_polish_never_worsens(env):
    """The rounding contract: for proposals good AND bad, the polished
    strategy's exact cost is <= the proposal's (valid never degrades to
    invalid; latency never rises)."""
    for mb in (1, 4, 8, 64):
        res = polish_strategy(env, _uniform(env, mb), cfg=QUICK)
        if res.pre_valid:
            assert res.valid
            assert res.latency <= res.pre_latency + 1e-12
        if res.improved:
            assert res.valid
            assert (not res.pre_valid) or res.latency < res.pre_latency


def test_polish_improves_a_mediocre_proposal(env):
    """A uniform mid-tile proposal leaves real latency on the table; the
    descent must find some of it (strict improvement, exact-scored)."""
    res = polish_strategy(env, _uniform(env, 8), cfg=QUICK)
    assert res.pre_valid and res.valid
    assert res.improved and res.latency < res.pre_latency


def test_polish_output_always_legal(env):
    """Every returned strategy is a legal serving strategy: position 0
    tiles, padding stays SYNC, tiles within [1, B] — including cells where
    the proposal was budget-violating and repair had to run."""
    rng = np.random.default_rng(0)
    props = np.stack([
        np.asarray(cm.random_strategy(rng, env.n, env.nmax, env.batch, 0.3),
                   np.int32) for _ in range(4)])
    wls = cm.stack_workloads([env.wl] * 4)
    out = polish_grid(wls, props, [float(env.batch)] * 4,
                      [0.02 * MB, 0.5 * MB, 4 * MB, 64 * MB],
                      [env.hw] * 4, cfg=QUICK)
    for c in range(4):
        s = out["strategy"][c]
        assert s[0] >= 1
        assert (s[env.n + 1:] == cm.SYNC).all()
        body = s[: env.n + 1]
        assert ((body == cm.SYNC) | ((body >= 1) & (body <= env.batch))).all()
        # the reported cost is the exact evaluator's view of the strategy
        # (peak is budget-independent; validity was judged per-cell budget)
        _, peak, _ = env.speedup(s)
        assert np.isclose(peak, out["peak_mem"][c], rtol=1e-6)


def test_polish_deterministic(env):
    """No RNG anywhere: identical inputs -> bit-identical outputs."""
    a = polish_strategy(env, _uniform(env, 8), cfg=QUICK)
    b = polish_strategy(env, _uniform(env, 8), cfg=QUICK)
    assert np.array_equal(a.strategy, b.strategy)
    assert a.latency == b.latency and a.peak_mem == b.peak_mem


def test_polish_lane_independent(env):
    """Grid polish of [s1, s2] equals the single-condition polishes: a
    lane's answer cannot depend on its neighbours (the §14 determinism
    contract polished serving rides on)."""
    s1, s2 = _uniform(env, 8), _uniform(env, 32)
    wls = cm.stack_workloads([env.wl, env.wl])
    grid = polish_grid(wls, np.stack([s1, s2]), [64.0, 64.0],
                       [4 * MB, 4 * MB], [env.hw, env.hw], cfg=QUICK)
    for i, s in enumerate((s1, s2)):
        single = polish_strategy(env, s, cfg=QUICK)
        assert np.array_equal(grid["strategy"][i], single.strategy)
        assert grid["latency"][i] == single.latency


def test_polish_never_below_certified_optimum():
    """Adversarial cross-check: on oracle-solvable conditions the polished
    latency must stay >= the certified optimum (polish refines within the
    map-space; it must never 'beat' ground truth, which would mean the
    smooth twin leaked into the exact score)."""
    from repro.core import optimal as op
    for name, wl, batch, budget, pack_hw, serve_hw in adv.cases():
        if name.startswith("boundary") or pack_hw is not serve_hw:
            continue          # f32 boundary flips / BPE rescale: §16 tests
        wl_np = adv.packed(wl, serve_hw)
        try:
            opt = op.optimal_search(wl_np, batch, float(budget), serve_hw,
                                    front_cap=4096)
        except RuntimeError:
            continue
        env = FusionEnv(wl, serve_hw, batch=batch,
                        budget_bytes=float(budget), nmax=adv.NMAX)
        s = np.full(adv.NMAX, cm.SYNC, np.int32)
        s[: env.n + 1] = max(1, batch // 2)
        res = polish_strategy(env, s, cfg=QUICK)
        if res.valid and opt.valid:
            assert res.latency >= opt.latency * (1 - 1e-5), name


def test_serving_opt_out_bit_identical():
    """polish=False / escalate=False (the defaults) serve BIT-IDENTICAL
    responses to an engine that has never heard of §17 — strategy bytes,
    latency floats, validity, and the compile/stats counters."""
    import jax
    from repro.core.model import DTConfig, dt_init
    from repro.serving import MapperEngine, MapRequest

    cfg = DTConfig(max_steps=64)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    reqs = [MapRequest(vgg16(), 64, 20 * MB, ACCEL_ZOO["edge"]),
            MapRequest(resnet18(), 32, 14 * MB, ACCEL_ZOO["mobile"])]
    base = MapperEngine(params, cfg).serve(reqs)
    off = MapperEngine(params, cfg, polish=False, escalate=False).serve(reqs)
    for a, b in zip(base, off):
        assert np.array_equal(a.strategy, b.strategy)
        assert a.latency == b.latency and a.peak_mem == b.peak_mem
        assert a.valid == b.valid and a.speedup == b.speedup


def test_engine_polish_counters_and_wins():
    """polish=True moves the §17 counters, never worsens any response,
    and logs harvestable wins only for valid improvements."""
    import jax
    from repro.core.model import DTConfig, dt_init
    from repro.serving import MapperEngine, MapRequest

    cfg = DTConfig(max_steps=32)
    params = dt_init(jax.random.PRNGKey(0), cfg)
    w = tiny_cnn()
    reqs = [MapRequest(w, 64, 2.5 * MB, ACCEL_ZOO["edge"]),
            MapRequest(w, 32, 1.5 * MB, ACCEL_ZOO["edge"])]
    plain = MapperEngine(params, cfg).serve(reqs)
    eng = MapperEngine(params, cfg, polish=True)
    out = eng.serve(reqs)
    s = eng.stats()
    assert s["polish_invocations"] == len(reqs)
    assert s["polish_improved"] >= 0
    for a, b in zip(plain, out):
        assert (not a.valid) or b.valid
        if a.valid and b.valid:
            assert b.latency <= a.latency + 1e-12
    for win in eng.wins:
        assert win["workload"].name == w.name
    got = eng.harvest_wins(workloads=[w])
    assert len(got) == s["polish_improved"] or not got
    assert not eng.wins                       # drained
